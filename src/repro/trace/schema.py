"""Trace schema: a serializable DAG of timed events (DESIGN.md §3).

One :class:`Trace` is the record of one measured step (a train step, a
serving run, one scaling-matrix cell): a list of :class:`TraceEvent`
nodes whose ``deps`` edges form a DAG, plus the measured wall-clock
samples the DAG was decomposed from, the provenance of the cell
(arch/shape/mesh/devices), an environment fingerprint (same
``env_fingerprint()`` as ``BenchRecord`` — traces from different hosts
are never silently comparable), and a schema version.

The JSON layout is deliberately flat (``json.dumps(trace.to_dict())``)
so traces survive the subprocess boundary the scaling matrix runs
behind, land in ``results/traces/`` as CI artifacts, and round-trip
byte-stable through :meth:`Trace.save` / :func:`load_trace`.
"""

from __future__ import annotations

import json
from dataclasses import asdict, dataclass, field
from pathlib import Path
from typing import Any, Dict, List, Tuple

from repro.bench.record import env_fingerprint

TRACE_SCHEMA_VERSION = 1

# event categories the replayer understands as parallel lanes
KINDS = ("compute", "memory", "collective", "prefill", "decode",
         "handoff", "host")


class TraceError(ValueError):
    """Malformed trace: duplicate/unknown event ids, cycles, bad costs."""


@dataclass
class TraceEvent:
    """One timed node of the DAG.

    ``kind`` is the resource lane (compute / memory / collective /
    prefill / decode / host), ``op`` the finer label (HLO opcode such as
    ``dot`` or ``all-reduce``, or a dispatch label), ``cost_s`` the time
    the event occupies its lane, and ``deps`` the event ids that must
    finish before this one starts.
    """

    eid: str
    kind: str
    op: str = ""
    cost_s: float = 0.0
    deps: Tuple[str, ...] = ()
    meta: Dict[str, Any] = field(default_factory=dict)

    def to_dict(self) -> Dict[str, Any]:
        d = asdict(self)
        d["deps"] = list(self.deps)
        return d

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "TraceEvent":
        return cls(
            eid=str(d["eid"]),
            kind=str(d.get("kind", "compute")),
            op=str(d.get("op", "")),
            cost_s=float(d.get("cost_s", 0.0)),
            deps=tuple(d.get("deps", ())),
            meta=dict(d.get("meta", {})),
        )


@dataclass
class Trace:
    """A captured, replayable step: DAG + measurement + provenance."""

    name: str
    kind: str = "train_step"  # train_step | serve | pp_step
    arch: str = ""
    shape: str = ""
    mesh: str = ""  # "2x4"-style (data x model)
    n_devices: int = 1
    measured_step_s: float = 0.0  # median of samples_s
    samples_s: List[float] = field(default_factory=list)
    events: List[TraceEvent] = field(default_factory=list)
    meta: Dict[str, Any] = field(default_factory=dict)
    env: Dict[str, Any] = field(default_factory=env_fingerprint)
    version: int = TRACE_SCHEMA_VERSION

    # --------------------------------------------------------- validation
    def validate(self) -> None:
        """Raise :class:`TraceError` on structural problems the replayer
        cannot recover from (duplicate ids, dangling deps, negative
        costs). Cycles are detected by :func:`repro.trace.replay.toposort`
        at replay time, where the offending ids can be named."""
        seen: set = set()
        for ev in self.events:
            if ev.eid in seen:
                raise TraceError(f"{self.name}: duplicate event id {ev.eid!r}")
            seen.add(ev.eid)
            if ev.cost_s < 0:
                raise TraceError(
                    f"{self.name}: event {ev.eid!r} has negative cost "
                    f"{ev.cost_s}"
                )
        for ev in self.events:
            for dep in ev.deps:
                if dep not in seen:
                    raise TraceError(
                        f"{self.name}: event {ev.eid!r} depends on unknown "
                        f"event {dep!r}"
                    )

    # ------------------------------------------------------------- lanes
    def lane_seconds(self, by: str = "kind") -> Dict[str, float]:
        """Total event cost per lane (kind) — the decomposed step.

        ``by="role"`` groups serve events by the serving role that
        issued them instead (``ev.meta["role"]``, falling back to the
        kind): under the disaggregated engine the same event kinds land
        on per-role lanes, which is what the interference comparison in
        ``benchmarks/bench_trace.py`` sums.
        """
        out: Dict[str, float] = {}
        for ev in self.events:
            key = ev.meta.get("role", ev.kind) if by == "role" else ev.kind
            out[key] = out.get(key, 0.0) + ev.cost_s
        return out

    def calibration(self) -> Dict[str, float]:
        """Host-effective rates measured by this trace, for the
        trace-driven ``mesh_advisor.advise(..., calibration=...)`` mode.

        Derived from the per-lane decomposition: the effective FLOP/s is
        the trace's HLO FLOPs over the time its compute lane actually
        took on this host (ditto bytes/HBM and ICI traffic), and
        ``useful_flops_scale`` is measured-HLO-FLOPs / analytic model
        FLOPs — the remat/attention overhead an analytic count misses.
        Lanes the trace never exercised fall back to the hardware peak
        discounted by the overall measured/roofline ratio."""
        from repro.core.roofline import (
            HBM_BW,
            ICI_BW_PER_LINK,
            PEAK_FLOPS_BF16,
        )

        lanes = self.lane_seconds()
        ratio = float(self.meta.get("calibration_ratio", 1.0)) or 1.0
        out: Dict[str, float] = {"calibration_ratio": ratio}

        def rate(amount_key: str, lane: str, peak: float) -> float:
            amount = float(self.meta.get(amount_key, 0.0))
            t = lanes.get(lane, 0.0)
            if amount > 0 and t > 0:
                return amount / t
            return peak / ratio

        out["flops_per_s"] = rate("flops", "compute", PEAK_FLOPS_BF16)
        out["hbm_bytes_per_s"] = rate("bytes", "memory", HBM_BW)
        out["ici_bytes_per_s"] = rate(
            "ici_bytes", "collective", ICI_BW_PER_LINK
        )
        model_flops = float(self.meta.get("model_flops", 0.0))
        flops_global = float(self.meta.get("flops", 0.0)) * self.n_devices
        if model_flops > 0 and flops_global > 0:
            out["useful_flops_scale"] = flops_global / model_flops
        return out

    # ------------------------------------------------------ serialization
    def to_dict(self) -> Dict[str, Any]:
        return {
            "name": self.name,
            "kind": self.kind,
            "arch": self.arch,
            "shape": self.shape,
            "mesh": self.mesh,
            "n_devices": self.n_devices,
            "measured_step_s": self.measured_step_s,
            "samples_s": list(self.samples_s),
            "events": [ev.to_dict() for ev in self.events],
            "meta": self.meta,
            "env": self.env,
            "version": self.version,
        }

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "Trace":
        version = int(d.get("version", 0))
        if version > TRACE_SCHEMA_VERSION:
            raise TraceError(
                f"trace schema v{version} is newer than this reader "
                f"(v{TRACE_SCHEMA_VERSION})"
            )
        return cls(
            name=str(d["name"]),
            kind=str(d.get("kind", "train_step")),
            arch=str(d.get("arch", "")),
            shape=str(d.get("shape", "")),
            mesh=str(d.get("mesh", "")),
            n_devices=int(d.get("n_devices", 1)),
            measured_step_s=float(d.get("measured_step_s", 0.0)),
            samples_s=[float(s) for s in d.get("samples_s", ())],
            events=[TraceEvent.from_dict(e) for e in d.get("events", ())],
            meta=dict(d.get("meta", {})),
            env=dict(d.get("env", {})),
            version=version or TRACE_SCHEMA_VERSION,
        )

    def to_json(self) -> str:
        return json.dumps(self.to_dict(), sort_keys=True)

    @classmethod
    def from_json(cls, text: str) -> "Trace":
        return cls.from_dict(json.loads(text))

    def save(self, path: str | Path) -> Path:
        """Atomic write (tmp + rename), like the bench JSONL sink."""
        path = Path(path)
        path.parent.mkdir(parents=True, exist_ok=True)
        tmp = path.with_suffix(path.suffix + ".tmp")
        tmp.write_text(self.to_json() + "\n")
        tmp.replace(path)
        return path


def load_trace(path: str | Path) -> Trace:
    trace = Trace.from_json(Path(path).read_text())
    trace.validate()
    return trace
