"""Trace capture: decompose a measured step into a replayable DAG.

Two recorders (DESIGN.md §3):

* **Train step** — :func:`capture_train_trace` times the real jitted
  train step with its own timers (Python timers can only see the jitted
  boundary, so intra-step structure cannot be timed directly), lowers
  the same step and runs ``core/hlo_analysis`` on the compiled module,
  then apportions the measured median across per-op events: each lane
  (compute / memory / collective) is a chain of the module's heaviest
  ops, costed at its roofline seconds times one measured/roofline
  calibration ratio. The lanes run in parallel between a root and a
  sink — the roofline overlap assumption made explicit as DAG
  structure — so the identity replay reconstructs the measured step
  and what-if edits shift real, named ops.
* **Serving** — :class:`TracingClock` wraps any engine clock
  (``WallClock`` or ``SimClock``) and records one event per
  prefill/decode charge at the engines' existing dispatch seam; no
  engine code changes. The resulting trace is a measured dispatch
  chain whose identity replay equals the engine's busy time.

:func:`capture_matrix_cell` runs the train-step recorder inside the
same subprocess-simulated device meshes the scaling matrix uses
(``XLA_FLAGS=--xla_force_host_platform_device_count=N``), one child per
device count, each child printing one trace JSON per split.
"""

from __future__ import annotations

import statistics
import time
from typing import Any, Dict, List, Optional, Sequence, Tuple

from repro.trace.schema import Trace, TraceEvent

# the reduced model the measured scaling matrix runs (bench_scaling_matrix)
MATRIX_REDUCE_KW = dict(layers=2, d_model=128, d_ff=256, vocab=512)


# --------------------------------------------------------- DAG decomposition
def _lane_items(
    by_op: Dict[str, float], rate: float, ops_per_lane: int
) -> List[Tuple[str, float]]:
    """Top ops of one lane as (op, roofline_seconds), heaviest first,
    the tail lumped into one "other" event so lane totals stay exact."""
    items = sorted(
        ((op, amt) for op, amt in by_op.items() if amt > 0),
        key=lambda kv: -kv[1],
    )
    head = items[: max(1, ops_per_lane - 1)]
    tail = sum(amt for _, amt in items[len(head) :])
    out = [(op, amt / rate) for op, amt in head]
    if tail > 0:
        out.append(("other", tail / rate))
    return out


def dag_from_cost_summary(
    summary: Dict[str, Any],
    measured_s: float,
    *,
    ops_per_lane: int = 6,
) -> Tuple[List[TraceEvent], Dict[str, float]]:
    """Build the lane DAG from an HLO cost summary + a measured step.

    ``summary`` carries per-device totals and per-op breakdowns from
    ``core/hlo_analysis`` (``flops_by_op``, ``bytes_by_op``,
    ``collective_ici_by_op``). Returns ``(events, extras)`` where
    ``extras`` holds the calibration ratio (measured over the roofline
    max-lane time) and the raw per-lane roofline seconds.
    """
    from repro.core.roofline import HBM_BW, ICI_BW_PER_LINK, PEAK_FLOPS_BF16

    lanes = {
        "compute": _lane_items(
            summary.get("flops_by_op", {}), PEAK_FLOPS_BF16, ops_per_lane
        ),
        "memory": _lane_items(
            summary.get("bytes_by_op", {}), HBM_BW, ops_per_lane
        ),
        "collective": _lane_items(
            summary.get("collective_ici_by_op", {}),
            ICI_BW_PER_LINK,
            ops_per_lane,
        ),
    }
    roofline_s = {
        kind: sum(s for _, s in items) for kind, items in lanes.items()
    }
    max_lane = max(roofline_s.values(), default=0.0)
    events: List[TraceEvent] = [
        TraceEvent("root", "host", "dispatch", 0.0)
    ]
    if max_lane <= 0:
        # nothing to decompose (no HLO summary): one opaque step event
        events.append(
            TraceEvent("step", "host", "step", measured_s, deps=("root",))
        )
        events.append(TraceEvent("sink", "host", "sync", 0.0, deps=("step",)))
        return events, {"calibration_ratio": 1.0, **{
            f"roofline_{k}_s": v for k, v in roofline_s.items()}}
    ratio = measured_s / max_lane
    tails: List[str] = []
    for kind, items in lanes.items():
        prev = "root"
        for i, (op, roof_s) in enumerate(items):
            eid = f"{kind}{i}:{op}"
            events.append(
                TraceEvent(
                    eid,
                    kind,
                    op,
                    roof_s * ratio,
                    deps=(prev,),
                    meta={"roofline_s": roof_s},
                )
            )
            prev = eid
        if prev != "root":
            tails.append(prev)
    events.append(TraceEvent("sink", "host", "sync", 0.0, deps=tuple(tails)))
    extras = {"calibration_ratio": ratio}
    for kind, v in roofline_s.items():
        extras[f"roofline_{kind}_s"] = v
    return events, extras


def cost_summary(report) -> Dict[str, Any]:
    """Wire format of a ``CostReport`` for trace metadata / subprocess
    transport: totals plus the per-op breakdowns the DAG builder eats."""
    return {
        "flops": report.flops,
        "dot_flops": report.dot_flops,
        "bytes": report.bytes,
        "ici_bytes": report.collective_ici_bytes,
        "flops_by_op": dict(report.flops_by_op),
        "bytes_by_op": dict(report.bytes_by_op),
        "collective_ici_by_op": report.collective_ici_summary(),
    }


def trace_from_cell_payload(
    payload: Dict[str, Any],
    *,
    name: str,
    arch: str = "",
    shape: str = "",
    mesh: str = "",
    n_devices: int = 1,
    kind: str = "train_step",
    ops_per_lane: int = 6,
) -> Trace:
    """Assemble a :class:`Trace` from one captured cell: measured
    ``samples_s`` + an HLO ``summary`` + cell ``meta``."""
    samples = [float(s) for s in payload["samples_s"]]
    measured = float(statistics.median(samples))
    events, extras = dag_from_cost_summary(
        payload.get("summary", {}), measured, ops_per_lane=ops_per_lane
    )
    meta = dict(payload.get("meta", {}))
    summary = payload.get("summary", {})
    for key in ("flops", "dot_flops", "bytes", "ici_bytes"):
        if key in summary:
            meta[key] = summary[key]
    meta.update(extras)
    trace = Trace(
        name=name,
        kind=kind,
        arch=arch,
        shape=shape,
        mesh=mesh,
        n_devices=n_devices,
        measured_step_s=measured,
        samples_s=samples,
        events=events,
        meta=meta,
    )
    trace.validate()
    return trace


# ------------------------------------------------------- train-step capture
def capture_train_trace(
    arch: str = "granite-3-8b",
    *,
    split: Tuple[int, int] = (1, 1),
    batch: int = 8,
    seq: int = 64,
    reduce_kw: Optional[Dict[str, int]] = None,
    iters: int = 5,
    warmup: int = 2,
    ops_per_lane: int = 6,
) -> Trace:
    """Capture one train-step trace on the current host devices.

    Mirrors the scaling-matrix cell exactly (same reduced model, same
    ``RunConfig`` knobs), but compiles ahead-of-time so the SAME
    compiled module is both timed and fed to ``core/hlo_analysis``.
    Requires ``jax.device_count() >= dp * tp``.
    """
    import jax
    from jax.sharding import NamedSharding

    from repro.configs import ARCHS, MeshConfig, RunConfig, ShapeConfig
    from repro.configs import reduced as reduce_cfg
    from repro.core.hlo_analysis import analyze_hlo
    from repro.core.profiler import model_flops_for
    from repro.launch.mesh import make_mesh, set_mesh
    from repro.models.frontends import synth_batch
    from repro.parallel import sharding as shd
    from repro.runtime.steps import build_train_step

    reduce_kw = dict(MATRIX_REDUCE_KW if reduce_kw is None else reduce_kw)
    cfg = reduce_cfg(ARCHS[arch], **reduce_kw)
    dp, tp = split
    n_devices = dp * tp
    if jax.device_count() < n_devices:
        raise RuntimeError(
            f"split {dp}x{tp} needs {n_devices} devices, host has "
            f"{jax.device_count()} (set "
            f"XLA_FLAGS=--xla_force_host_platform_device_count={n_devices})"
        )
    mesh_cfg = MeshConfig(shape=split, axes=("data", "model"))
    shape = ShapeConfig("trace", "train", seq, batch)
    rcfg = RunConfig(
        model=cfg,
        shape=shape,
        mesh=mesh_cfg,
        param_dtype="float32",
        attention_backend="dense",
        exec_mode="resident",
    )
    mesh = make_mesh(mesh_cfg)
    with set_mesh(mesh):
        step, model, opt = build_train_step(rcfg)
        params = model.init_params(jax.random.PRNGKey(0))
        pspecs = shd.param_pspecs(params, cfg, rcfg)
        params = jax.tree.map(
            lambda x, sp: jax.device_put(x, NamedSharding(mesh, sp)),
            params,
            pspecs,
            is_leaf=lambda x: not isinstance(x, dict),
        )
        opt_state = opt.init(params)
        data = synth_batch(cfg, batch, seq, kind="train")
        compiled = jax.jit(step).lower(params, opt_state, data).compile()
        hlo_report = analyze_hlo(compiled.as_text())
        args = (params, opt_state, data)
        for _ in range(warmup):
            jax.block_until_ready(compiled(*args))
        samples = []
        for _ in range(iters):
            # train capture measures the real jitted step; the Sim-clock
            # discipline only binds the serving path (TracingClock below)
            t0 = time.perf_counter()  # repro: allow=RS104
            jax.block_until_ready(compiled(*args))
            samples.append(time.perf_counter() - t0)  # repro: allow=RS104
    payload = {
        "samples_s": samples,
        "summary": cost_summary(hlo_report),
        "meta": {
            "model_flops": model_flops_for(cfg, shape),
            "param_count": float(cfg.param_count()),
            "d_model": cfg.d_model,
            "layers": cfg.num_layers + cfg.encoder_layers,
            "heads": cfg.num_heads,
            "tokens": batch * seq,
            "batch": batch,
            "seq": seq,
            "split": [dp, tp],
            "reduce_kw": reduce_kw,
        },
    }
    return trace_from_cell_payload(
        payload,
        name=f"train/{arch}/{dp}x{tp}",
        arch=arch,
        shape=shape.name,
        mesh=f"{dp}x{tp}",
        n_devices=n_devices,
        ops_per_lane=ops_per_lane,
    )


_CELL_CODE = r"""
import json
from repro.trace.capture import capture_train_trace

for split in {splits!r}:
    tr = capture_train_trace(
        arch={arch!r}, split=tuple(split), batch={batch}, seq={seq},
        reduce_kw={reduce_kw!r}, iters={iters}, warmup={warmup})
    print(tr.to_json())
"""


def capture_matrix_cell(
    n_devices: int,
    splits: Sequence[Tuple[int, int]],
    *,
    arch: str = "granite-3-8b",
    batch: int = 8,
    seq: int = 64,
    reduce_kw: Optional[Dict[str, int]] = None,
    iters: int = 5,
    warmup: int = 2,
    timeout: int = 900,
) -> List[Trace]:
    """Capture train-step traces for ``splits`` inside one simulated
    ``n_devices``-host child process (the scaling-matrix transport:
    ``repro.bench.runner.run_with_devices``)."""
    from repro.bench.runner import run_with_devices

    code = _CELL_CODE.format(
        splits=[list(s) for s in splits],
        arch=arch,
        batch=batch,
        seq=seq,
        reduce_kw=dict(MATRIX_REDUCE_KW if reduce_kw is None else reduce_kw),
        iters=iters,
        warmup=warmup,
    )
    out: List[Trace] = []
    for line in run_with_devices(
        code, n_devices=n_devices, timeout=timeout
    ).splitlines():
        line = line.strip()
        if line.startswith("{"):
            trace = Trace.from_json(line)
            trace.validate()
            out.append(trace)
    return out


# ----------------------------------------------------------- serving capture
class TracingClock:
    """Record the serving engines' prefill/decode dispatches as trace
    events, from the clock seam every engine already charges.

    Wraps any engine clock (``WallClock``, ``SimClock``): ``charge`` is
    called exactly once per prefill-chunk dispatch and per pool decode
    step (``serving/engine.py``, ``serving/paged.py``), so the elapsed
    inner-clock time since the previous charge/wait IS that dispatch's
    cost — real dispatch+host time under a wall clock, the deterministic
    charged cost under a sim clock. Idle waits (``wait_until``) advance
    the mark without emitting events, so the trace records busy time
    only.
    """

    def __init__(self, inner=None) -> None:
        if inner is None:
            from repro.serving.request import WallClock

            inner = WallClock()
        self.inner = inner
        self.events: List[TraceEvent] = []
        self._mark = inner.now()
        self._prev: Optional[str] = None

    def now(self) -> float:
        return self.inner.now()

    def charge(self, kind: str, n: int = 1, role: Optional[str] = None) -> None:
        self.inner.charge(kind, n)
        t1 = self.inner.now()
        cost = max(t1 - self._mark, 0.0)
        eid = f"{kind}{len(self.events)}"
        self.events.append(
            TraceEvent(
                eid,
                kind,
                kind,
                cost,
                deps=(self._prev,) if self._prev else (),
                meta={"n": n, "role": role or kind},
            )
        )
        self._mark = t1
        self._prev = eid

    def wait_until(self, t: float) -> None:
        self.inner.wait_until(t)
        self._mark = self.inner.now()

    def trace(self, name: str = "serve", **provenance) -> Trace:
        """The recorded dispatch chain as a replayable trace; the
        measured step is the engine's total busy (charged) time."""
        busy = sum(ev.cost_s for ev in self.events)
        lanes: Dict[str, int] = {}
        for ev in self.events:
            lanes[ev.kind] = lanes.get(ev.kind, 0) + 1
        trace = Trace(
            name=name,
            kind="serve",
            measured_step_s=busy,
            samples_s=[busy],
            events=list(self.events),
            meta={"busy_s": busy, "dispatches": dict(lanes)},
            **provenance,
        )
        trace.validate()
        return trace
