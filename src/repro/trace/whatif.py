"""What-if edits and cross-split prediction over captured traces.

Three edit factories (:func:`scale_op`, :func:`scale_kind`,
:func:`set_cost`) answer local questions — "step time if matmuls were
2x faster" — by rescaling event costs inside the captured DAG and
replaying it.

:func:`predict_split` answers the global question — "step time under a
different (data, model) split" — by re-costing the trace's three lanes
with first-principles scaling rules at the trace's own calibrated rates
and replaying the re-costed lane DAG (the prediction is a replay, not a
formula: the same earliest-start walk the identity gate validates).
Scaling rules (DESIGN.md §3):

* compute: per-device FLOPs scale with 1/devices;
* memory: the weight-read share (``min(1, 3 x param_bytes / traffic)``)
  scales with the model split, the activation share with the data
  split;
* collectives: re-derived analytically (Megatron activation psums for
  TP, gradient all-reduce for DP, ring formulas) at the trace's
  calibrated ICI rate.

Cross-split error against the measured simulated-host matrix is
*reported* (EXPERIMENTS.md §Trace-replay), not CI-gated: simulated
hosts multiplex every "device" onto shared cores, so measured cells
include host contention no per-device cost model represents
(DESIGN.md §4). The CI gate is the per-cell identity replay.

:func:`advise_from_trace` is the trace-driven ``mesh_advisor`` mode:
it rebuilds the traced model config and feeds ``advise()`` the trace's
measured calibration instead of hardware peaks.
"""

from __future__ import annotations

from typing import List, Optional, Sequence, Tuple

from repro.trace.replay import Edit, ReplayResult, replay
from repro.trace.schema import Trace, TraceError, TraceEvent


# --------------------------------------------------------------- edits
def scale_op(op: str, factor: float) -> Edit:
    """Scale every event whose ``op`` matches by ``factor``."""

    def edit(ev: TraceEvent, cost_s: float) -> float:
        return cost_s * factor if ev.op == op else cost_s

    return edit


def scale_kind(kind: str, factor: float) -> Edit:
    """Scale every event on one lane (``kind``) by ``factor``."""

    def edit(ev: TraceEvent, cost_s: float) -> float:
        return cost_s * factor if ev.kind == kind else cost_s

    return edit


def set_cost(eid: str, cost_s: float) -> Edit:
    """Pin one event's cost to an absolute value."""

    def edit(ev: TraceEvent, old: float) -> float:
        return cost_s if ev.eid == eid else old

    return edit


# ------------------------------------------------------ split prediction
def predict_split(
    trace: Trace, split: Tuple[int, int]
) -> ReplayResult:
    """Predict step time under a different (data, model) split by
    re-costing the trace's lanes and replaying them.

    Requires a train-step trace captured by
    :func:`repro.trace.capture.capture_train_trace` (needs ``split``,
    ``param_count``, ``d_model``, ``layers``, ``tokens`` in ``meta``).
    """
    meta = trace.meta
    for key in ("split", "param_count", "d_model", "layers", "tokens"):
        if key not in meta:
            raise TraceError(
                f"{trace.name}: meta lacks {key!r}; predict_split needs a "
                "capture_train_trace trace"
            )
    ref_dp, ref_tp = (int(x) for x in meta["split"])
    dp, tp = int(split[0]), int(split[1])
    if dp < 1 or tp < 1:
        raise TraceError(f"bad split {split!r}")
    ref_n, n = ref_dp * ref_tp, dp * tp
    lanes = trace.lane_seconds()
    cal = trace.calibration()

    # compute: per-device FLOPs shrink with the device count
    compute_s = lanes.get("compute", 0.0) * ref_n / n

    # memory: split measured traffic into weight reads (scale with the
    # model split) and activation traffic (scales with the data split)
    param_bytes = float(meta["param_count"]) * 4.0  # float32 params
    traffic = float(meta.get("bytes", 0.0))
    w_share = min(1.0, 3.0 * param_bytes / traffic) if traffic > 0 else 0.5
    mem_ref = lanes.get("memory", 0.0)
    memory_s = (
        mem_ref * w_share * ref_tp / tp
        + mem_ref * (1.0 - w_share) * ref_dp / dp
    )

    # collectives: re-derived from first principles at the calibrated
    # ICI rate (the reference lane may be empty — 1x1 has no
    # collectives — so scaling it would predict zero forever)
    L = float(meta["layers"])
    d = float(meta["d_model"])
    tokens = float(meta["tokens"])
    ici_rate = float(cal.get("ici_bytes_per_s", 1.0)) or 1.0
    coll_bytes = 0.0
    if tp > 1:  # Megatron psums: 4 sites/layer, fwd+bwd, ring all-reduce
        coll_bytes += (
            4.0 * L * (tokens / dp) * d * 2.0 * 2.0 * (tp - 1) / tp
        )
    if dp > 1:  # fp32 gradient all-reduce over the data axis
        coll_bytes += param_bytes * 2.0 * (dp - 1) / dp
    collective_s = coll_bytes / ici_rate

    events = [TraceEvent("root", "host", "dispatch", 0.0)]
    for kind, cost in (
        ("compute", compute_s),
        ("memory", memory_s),
        ("collective", collective_s),
    ):
        events.append(
            TraceEvent(kind, kind, f"{kind}@{dp}x{tp}", cost, deps=("root",))
        )
    events.append(
        TraceEvent(
            "sink", "host", "sync", 0.0,
            deps=("compute", "memory", "collective"),
        )
    )
    mini = Trace(
        name=f"{trace.name}->whatif/{dp}x{tp}",
        kind=trace.kind,
        arch=trace.arch,
        shape=trace.shape,
        mesh=f"{dp}x{tp}",
        n_devices=n,
        events=events,
        meta={"ref_split": [ref_dp, ref_tp], "split": [dp, tp]},
        env=dict(trace.env),
    )
    return replay(mini)


# ------------------------------------------------------- advisor bridge
def advise_from_trace(
    trace: Trace,
    n_devices: Optional[int] = None,
    *,
    candidates: Optional[Sequence[int]] = None,
) -> List:
    """Rank splits with ``core.mesh_advisor.advise`` running on the
    trace's measured rates instead of hardware peaks.

    Rebuilds the traced model config from the trace's provenance
    (``arch`` + ``meta["reduce_kw"]``), then passes
    ``Trace.calibration()`` through the advisor's ``calibration=``
    seam. Returns the advisor's ``MeshAdvice`` ranking.
    """
    from repro.configs import ARCHS, ShapeConfig
    from repro.configs import reduced as reduce_cfg
    from repro.core.mesh_advisor import advise

    if not trace.arch or trace.arch not in ARCHS:
        raise TraceError(
            f"{trace.name}: unknown arch {trace.arch!r}; advise_from_trace "
            "needs a trace captured against a registered arch"
        )
    cfg = ARCHS[trace.arch]
    reduce_kw = trace.meta.get("reduce_kw")
    if reduce_kw:
        cfg = reduce_cfg(cfg, **{k: int(v) for k, v in reduce_kw.items()})
    batch = int(trace.meta.get("batch", 8))
    seq = int(trace.meta.get("seq", 64))
    kind = "train" if trace.kind == "train_step" else "decode"
    shape = ShapeConfig("trace", kind, seq, batch)
    return advise(
        cfg,
        shape,
        n_devices if n_devices is not None else trace.n_devices,
        candidates=list(candidates) if candidates is not None else None,
        calibration=trace.calibration(),
    )
