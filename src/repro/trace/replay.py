"""DAG replay: predict step time by walking the trace (DESIGN.md §3).

The replayer runs an earliest-start schedule over the event DAG: an
event starts when its last dependency finishes and occupies its cost;
the predicted step time is the latest finish. With no edits this
reconstructs the recorded step (identity replay — the property
``tools/ci_checks.py trace-replay-error`` gates per scaling-matrix
cell); with edits (:mod:`repro.trace.whatif`) it answers what-if
questions — "step time if this op were twice as fast / this split were
2x4" — without running the config.

Edits are callables ``edit(event, cost_s) -> cost_s`` applied in order
to every event; costs can only be inspected and replaced, never the DAG
shape, so a replayed prediction is always over the captured dependency
structure. Halving any cost can therefore never increase the predicted
time (the monotonicity property ``tests/test_trace.py`` checks).
"""

from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Sequence

from repro.trace.schema import Trace, TraceError, TraceEvent

Edit = Callable[[TraceEvent, float], float]


@dataclass
class ReplayResult:
    """One replay: the prediction plus the schedule that produced it."""

    predicted_s: float
    finish_s: Dict[str, float]  # eid -> finish time
    critical_path: List[str]  # eids, source -> sink
    lane_s: Dict[str, float] = field(default_factory=dict)

    @property
    def dominant_lane(self) -> str:
        """Lane carrying the most critical-path time."""
        if not self.lane_s:
            return ""
        return max(self.lane_s, key=lambda k: self.lane_s[k])


def toposort(events: Sequence[TraceEvent]) -> List[TraceEvent]:
    """Kahn's algorithm over ``deps`` edges; events may arrive in any
    order. Raises :class:`TraceError` naming the stuck events on a
    cycle (and on dangling deps, via the indegree bookkeeping)."""
    by_id = {ev.eid: ev for ev in events}
    indeg: Dict[str, int] = {ev.eid: 0 for ev in events}
    out_edges: Dict[str, List[str]] = {ev.eid: [] for ev in events}
    for ev in events:
        for dep in ev.deps:
            if dep not in by_id:
                raise TraceError(
                    f"event {ev.eid!r} depends on unknown event {dep!r}"
                )
            indeg[ev.eid] += 1
            out_edges[dep].append(ev.eid)
    ready = deque(eid for eid, n in indeg.items() if n == 0)
    order: List[TraceEvent] = []
    while ready:
        eid = ready.popleft()
        order.append(by_id[eid])
        for nxt in out_edges[eid]:
            indeg[nxt] -= 1
            if indeg[nxt] == 0:
                ready.append(nxt)
    if len(order) != len(events):
        stuck = sorted(eid for eid, n in indeg.items() if n > 0)
        raise TraceError(f"dependency cycle through events {stuck}")
    return order


def replay(trace: Trace, *, edits: Sequence[Edit] = ()) -> ReplayResult:
    """Earliest-start walk over the DAG under optional cost edits."""
    trace.validate()
    order = toposort(trace.events)
    finish: Dict[str, float] = {}
    cost: Dict[str, float] = {}
    last_dep: Dict[str, str] = {}  # eid -> dep that gated its start
    for ev in order:
        c = ev.cost_s
        for edit in edits:
            c = float(edit(ev, c))
        if c < 0:
            raise TraceError(f"edit drove event {ev.eid!r} cost negative")
        start = 0.0
        for dep in ev.deps:
            if finish[dep] >= start:
                # ties resolve to the later-listed dep; any gating dep
                # yields a valid critical path
                start = finish[dep]
                last_dep[ev.eid] = dep
        cost[ev.eid] = c
        finish[ev.eid] = start + c
    if not finish:
        return ReplayResult(0.0, {}, [])
    sink = max(finish, key=lambda eid: finish[eid])
    path: List[str] = []
    cur: str | None = sink
    while cur is not None:
        path.append(cur)
        cur = last_dep.get(cur)
    path.reverse()
    by_id = {ev.eid: ev for ev in trace.events}
    lane_s: Dict[str, float] = {}
    for eid in path:
        kind = by_id[eid].kind
        lane_s[kind] = lane_s.get(kind, 0.0) + cost[eid]
    return ReplayResult(
        predicted_s=finish[sink],
        finish_s=finish,
        critical_path=path,
        lane_s=lane_s,
    )
