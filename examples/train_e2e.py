"""End-to-end training driver: a ~100M-parameter model trained for a few
hundred steps with the full production substrate — sharded params,
microbatched gradient accumulation, deterministic data pipeline, async
checkpointing, auto-resume and the straggler watchdog.

    PYTHONPATH=src python examples/train_e2e.py [--steps 300]

(Re-run the same command to watch it resume from the checkpoint.)
"""
import argparse
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.launch.train import main as train_main


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_e2e_ckpt")
    args = ap.parse_args()
    # ~100M params: 8 layers x d_model 768 (granite-family block)
    res = train_main([
        "--arch", "granite-3-8b",
        "--steps", str(args.steps),
        "--batch", "16", "--seq", "256",
        "--layers", "8", "--d-model", "768",
        "--microbatches", "2",
        "--ckpt-dir", args.ckpt_dir,
        "--ckpt-every", "100",
        "--lr", "6e-4",
    ])
    print(f"\ntrained to step {res.final_step}; "
          f"loss {res.losses[0]:.3f} -> {res.losses[-1]:.3f}; "
          f"checkpoints at {args.ckpt_dir}: {res.checkpoints}")


if __name__ == "__main__":
    main()
