"""Request-level serving example: a synthetic Poisson request stream
through the continuous-batching (or lockstep static) scheduler — KV-slot
pool, per-request TTFT / per-token latency, goodput (ring-buffer cache
for the sliding-window hybrid arch; recurrent state for rwkv6).

    PYTHONPATH=src python examples/serve_batch.py [--arch hymba-1.5b]
                                                  [--scheduler static]
"""
import argparse
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.launch.serve import main as serve_main


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="hymba-1.5b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--scheduler", default="continuous",
                    choices=("static", "continuous"))
    ap.add_argument("--offered-load", type=float, default=0.0)
    args = ap.parse_args()
    serve_main(["--arch", args.arch, "--batch", str(args.batch),
                "--scheduler", args.scheduler,
                "--offered-load", str(args.offered_load),
                "--prompt-len", "64", "--max-new-tokens", "32"])


if __name__ == "__main__":
    main()
