"""DABench-LLM in one command: run the Tier-1 + Tier-2 analysis for an
architecture and print the paper-style report (allocation ratio, load
imbalance per compile mode, arithmetic intensity, roofline verdict).

    PYTHONPATH=src python examples/dabench_report.py --arch arctic-480b
"""
import argparse
import json
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parents[1]
sys.path.insert(0, str(REPO / "src"))

from repro.configs import ARCHS, MeshConfig, SHAPES
from repro.core import profile
from repro.core.report import bench_table, load_bench_records, md_table


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-8b", choices=sorted(ARCHS))
    ap.add_argument("--shape", default="train_4k", choices=sorted(SHAPES))
    args = ap.parse_args()
    cfg, shape = ARCHS[args.arch], SHAPES[args.shape]
    mesh = MeshConfig()

    # Tier-1 structural profile (always available), rendered from the same
    # BenchRecord rows the benchmark harness emits
    rep = profile(cfg, shape, mesh)
    records = rep.to_records()
    print(f"# DABench-LLM report — {cfg.name} / {shape.name} / 16x16\n")
    print(f"params: {cfg.param_count() / 1e9:.1f}B "
          f"(active {cfg.active_param_count() / 1e9:.1f}B)   "
          f"AI (Eq.5): {rep.arithmetic_intensity:.1f} FLOPs/B\n")
    sections = [r for r in records if r.scenario == "tier1/sections"]
    print(bench_table(sections,
                      columns=["n_sections", "allocation", "LI",
                               "runtime_s"]))

    # Tier-1 compiled profile, if the dry-run artifact exists
    f = REPO / "results" / "dryrun" / f"{cfg.name}_{shape.name}_16x16.json"
    if f.exists():
        rl = json.loads(f.read_text())["roofline"]
        print(f"\ncompiled roofline: compute={rl['compute_s']:.2e}s "
              f"memory={rl['memory_s']:.2e}s "
              f"collective={rl['collective_s']:.2e}s "
              f"-> {rl['dominant']}-bound, MFU={rl['mfu']:.3f}")
    else:
        print("\n(run `python -m repro.launch.dryrun --arch ... --shape ...`"
              " for the compiled roofline)")

    # Measured results from the last benchmark-harness run, if any
    bench = [r for r in load_bench_records(
                 REPO / "results" / "bench" / "latest.jsonl")
             if not r.arch or r.arch == cfg.name]
    if bench:
        print(f"\nlast `benchmarks.run` records touching {cfg.name}:")
        print(bench_table(bench[:12]))

    # Tier-2 deployment guidance: analytic mesh ranking (validated against
    # the measured §Perf results in tests/test_advisor.py)
    if shape.kind == "train":
        from repro.core.mesh_advisor import advise
        print("\nmesh advisor (256 chips):")
        rows = [["x".join(map(str, a.mesh.shape)), a.microbatches,
                 f"{a.step_s:.2f}s", a.dominant, f"{a.hbm_gb:.1f}",
                 "yes" if a.fits else "NO"]
                for a in advise(cfg, shape)[:5]]
        print(md_table(["mesh", "mb", "roofline step", "dominant",
                        "HBM GB", "fits"], rows))


if __name__ == "__main__":
    main()
