"""Quickstart: build any assigned architecture (reduced size), run a loss,
train a few steps, then profile it with the DABench Tier-1 engine.

    PYTHONPATH=src python examples/quickstart.py [--arch rwkv6-3b]
"""
import argparse
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, MeshConfig, SHAPES, reduced
from repro.core import profile
from repro.models import build, Runtime
from repro.models.frontends import synth_batch


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-8b", choices=sorted(ARCHS))
    args = ap.parse_args()

    # 1. build a reduced config of the chosen architecture
    cfg = reduced(ARCHS[args.arch])
    print(f"arch={cfg.name} family={cfg.family} "
          f"(full config: {ARCHS[args.arch].param_count() / 1e9:.1f}B params)")

    model = build(cfg, Runtime(attention_backend="dense"), jnp.float32)
    params = model.init_params(jax.random.PRNGKey(0))
    batch = synth_batch(cfg, batch=4, seq=64, kind="train")

    # 2. one forward loss
    loss, aux = jax.jit(model.loss)(params, batch)
    print(f"initial loss: {float(loss):.4f}")

    # 3. a few training steps through the production step builder
    from repro.configs import RunConfig, ShapeConfig
    from repro.runtime.steps import build_train_step
    rcfg = RunConfig(model=cfg, shape=ShapeConfig("t", "train", 64, 4),
                     mesh=MeshConfig(shape=(1, 1), axes=("data", "model")),
                     param_dtype="float32", attention_backend="dense",
                     learning_rate=1e-3, warmup_steps=5)
    step, model2, opt = build_train_step(rcfg)
    p, o = model2.init_params(jax.random.PRNGKey(0)), None
    o = opt.init(p)
    jit_step = jax.jit(step, donate_argnums=(0, 1))
    for i in range(10):
        p, o, metrics = jit_step(p, o, batch)
        if i % 3 == 0:
            print(f"  step {i}: loss={float(metrics['loss']):.4f}")

    # 4. DABench Tier-1 profile of the FULL config on the production mesh
    rep = profile(ARCHS[args.arch], SHAPES["train_4k"], MeshConfig())
    print("\nTier-1 profile (full config, 16x16 mesh):")
    print(f"  arithmetic intensity (Eq.5): {rep.arithmetic_intensity:.1f}")
    for mode, sec in rep.sections.items():
        print(f"  {mode}: {sec['n_sections']:4d} sections  "
              f"allocation={sec['allocation']:.3f}  "
              f"LI={sec['load_imbalance']:.3f}")


if __name__ == "__main__":
    main()
